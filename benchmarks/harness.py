"""Shared benchmark harness: store construction per the paper's memory
budgeting, workload execution, and the modeled-NVMe throughput metric.

The container has no NVMe array, so cross-system comparisons use the I/O
model (4 KiB-block accounting identical to the paper's /proc/io method)
against the paper's testbed: 4x Samsung PM9A3 in RAID-0:

    read BW 6.8 GB/s/disk, rand-read 625 KIOPS/disk, write BW 2.0 GB/s/disk

modeled step time = max(read_ops/IOPS, read_bytes/readBW) + write_bytes/writeBW
modeled kops      = ops / modeled time  (CPU assumed off the critical path,
which Fig 1 of the paper establishes for CPU-optimized designs).
Wall-clock CPU ops/s of the tensorized store is reported alongside.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import numpy as np

from repro.core import KV, F2Config, OP_UPSERT
from repro.core.rebalance import RebalanceConfig
from repro.core.replication import ReplicatedKV
from repro.core.sharded import ShardedKV
from repro.serve.serve_step import (ServiceConfig, make_kv_service,
                                    make_session_service)
from .ycsb import Zipf, make_ops

N_DISKS = 4
READ_BW = 6.8e9 * N_DISKS
WRITE_BW = 2.0e9 * N_DISKS
READ_IOPS = 625e3 * N_DISKS


def _p2(x: int) -> int:
    p = 1
    while p < x:
        p *= 2
    return p


def make_f2_config(n_keys: int, mem_frac: float = 0.10,
                   value_width: int = 25, chunk_slots: int = 32,
                   rc_frac: float = 0.17, index_frac: float = 0.17,
                   rc_enabled: bool = True,
                   engine: str = "fused") -> F2Config:
    """Split the memory budget like the paper's S8.1 F2 configuration:
    ~1/6 hot index, ~1/6 read cache, ~1/2 hot-log memory, small cold-log
    and chunk-log windows; hot disk budget n/6, cold 7n/6.

    `engine` selects the probe/write backend (`jnp`, `fused`, `fused_ref`,
    `fused_pallas`) so every fig benchmark can sweep fused vs unfused."""
    rec = 16 + 4 * value_width
    budget = int(n_keys * rec * mem_frac)
    hot_index = _p2(max(256, int(budget * index_frac / 8)))
    rc = _p2(max(2, int(budget * rc_frac / rec))) if rc_enabled else 1
    hot_mem = _p2(max(64, int(budget * 0.5 / rec)))
    cold_mem = _p2(max(32, hot_mem // 16))
    n_chunks = _p2(max(64, n_keys // chunk_slots))
    chunklog_mem = _p2(max(32, int(budget * 0.03 / (8 * chunk_slots))))
    return F2Config(
        hot_index_size=hot_index,
        hot_capacity=_p2(max(2 * hot_mem, n_keys // 4)),
        hot_mem=hot_mem,
        cold_capacity=_p2(2 * n_keys),
        cold_mem=cold_mem,
        n_chunks=n_chunks,
        chunk_slots=chunk_slots,
        chunklog_capacity=_p2(max(4 * n_chunks, 256)),
        chunklog_mem=chunklog_mem,
        rc_capacity=rc,
        value_width=value_width,
        chain_max=48,
        engine=engine,
    )


def make_faster_config(n_keys: int, mem_frac: float = 0.10,
                       value_width: int = 25,
                       engine: str = "fused") -> F2Config:
    """FASTER (paper S8.1): fixed index ~1/3 of budget, log memory ~2/3.
    The log DISK budget is ~1.33x the dataset (paper: 40 GiB for 30 GiB),
    so steady-state updates force regular single-log compactions — the
    Fig 2 behavior.  (The ring itself gets 2x headroom: compaction appends
    live records before truncating.)"""
    rec = 16 + 4 * value_width
    budget = int(n_keys * rec * mem_frac)
    return F2Config(
        hot_index_size=_p2(max(256, int(budget / 3 / 8))),
        hot_capacity=_p2(2 * n_keys),
        hot_mem=_p2(max(64, int(budget * 2 / 3 / rec))),
        cold_capacity=2, cold_mem=1, n_chunks=2, chunklog_capacity=2,
        chunklog_mem=1, rc_capacity=1,
        value_width=value_width, chain_max=64, engine=engine,
    )


# Effective steady-state log budget: the paper gives FASTER 40 GiB for a
# 30 GiB dataset; dead-version inflation keeps it at the budget, compacting
# continuously (Fig 2).  At bench scale the higher in-place absorption of a
# small mutable window delays that equilibrium, so 1.2x reproduces the
# same churn regime (EXPERIMENTS.md notes the scaling).
FASTER_DISK_BUDGET_FRAC = 1.2


def make_faster_kv(n_keys: int, mem_frac: float = 0.10,
                   value_width: int = 25, batch: int = 4096,
                   compaction: str = "lookup",
                   engine: str = "fused") -> KV:
    cfg = make_faster_config(n_keys, mem_frac, value_width, engine=engine)
    kv = KV(cfg, mode="faster", faster_compaction=compaction,
            compact_batch=batch,
            # trigger as a fraction of the ring is scaled so the effective
            # disk budget is FASTER_DISK_BUDGET_FRAC * dataset
            trigger=FASTER_DISK_BUDGET_FRAC * n_keys / cfg.hot_capacity,
            compact_frac=0.15)
    return kv


def _shard_cfg(n_keys: int, n_shards: int, mem_frac: float,
               value_width: int, engine: str, rc_frac: float,
               index_frac: float, lanes, mode: str) -> F2Config:
    """One per-shard config recipe for every multi-store facade (sharded
    AND replicated bench stores build through it, so they stay tuned
    identically): size each shard for its n_keys/S key slice, then keep
    hot-ring headroom well above `lanes` — a shard must absorb one full
    sub-batch of appends between scheduler passes."""
    shard_keys = max(n_keys // n_shards, 256)
    if mode == "faster":
        # FASTER's single log needs 2x-dataset ring headroom (compaction
        # appends live records before truncating) — use its own budgeting
        cfg = make_faster_config(shard_keys, mem_frac, value_width,
                                 engine=engine)
    else:
        cfg = make_f2_config(shard_keys, mem_frac, value_width,
                             engine=engine, rc_frac=rc_frac,
                             index_frac=index_frac)
    if lanes:
        min_cap = _p2(8 * lanes)
        if cfg.hot_capacity < min_cap:
            cfg = dataclasses.replace(cfg, hot_capacity=min_cap)
    return cfg


def make_sharded_kv(n_keys: int, n_shards: int, mem_frac: float = 0.10,
                    value_width: int = 25, engine: str = "fused",
                    lanes: int = None, dispatch: str = "auto",
                    rc_frac: float = 0.17, index_frac: float = 0.17,
                    mode: str = "f2",
                    rebalance_cfg: RebalanceConfig = None, **kw) -> ShardedKV:
    """S hash-partitioned shards, each sized for its n_keys/S key slice
    under the same S8.1 memory split.  `lanes` caps per-shard sub-batch
    width (None = incoming batch width, single-round routing); ShardedKV
    is API-compatible with KV, so `load_store`/`run_workload` drive it
    unchanged.  `rebalance_cfg` arms the live rebalancer
    (`core.rebalance.RebalanceConfig`); per-shard occupancy/traffic stats
    are always collected and surfaced via `kv.shard_stats()` — the one
    struct both the rebalancer and the benchmarks consume."""
    shard_keys = max(n_keys // n_shards, 256)
    cfg = _shard_cfg(n_keys, n_shards, mem_frac, value_width, engine,
                     rc_frac, index_frac, lanes, mode)
    if mode == "faster":
        # same effective-disk-budget trigger as make_faster_kv (computed
        # from the FINAL ring capacity) so sharded-FASTER numbers stay
        # comparable to the unsharded baseline
        kw.setdefault("trigger",
                      FASTER_DISK_BUDGET_FRAC * shard_keys
                      / cfg.hot_capacity)
        kw.setdefault("faster_compaction", "lookup")
        kw.setdefault("compact_frac", 0.15)
    sc = ServiceConfig(n_shards=n_shards, lanes=lanes, dispatch=dispatch,
                       rebalance_cfg=rebalance_cfg,
                       store_kwargs=dict(mode=mode, **kw))
    return make_kv_service(cfg, sc)


def make_replicated_kv(n_keys: int, n_shards: int, n_replicas: int = 2,
                       read_selector: str = "round_robin",
                       mem_frac: float = 0.10, value_width: int = 25,
                       engine: str = "fused", lanes: int = None,
                       dispatch: str = "auto", rc_frac: float = 0.17,
                       index_frac: float = 0.17, **kw) -> ReplicatedKV:
    """R replica copies of the `make_sharded_kv` store (each replica holds
    a full copy of every shard — the paper's read-cache idea at cluster
    scale).  Builds through the same `_shard_cfg` recipe, so replicated
    and sharded bench stores stay tuned identically; `read_selector`
    picks the fan-out policy."""
    cfg = _shard_cfg(n_keys, n_shards, mem_frac, value_width, engine,
                     rc_frac, index_frac, lanes, mode="f2")
    sc = ServiceConfig(n_shards=n_shards, lanes=lanes, dispatch=dispatch,
                       n_replicas=n_replicas, read_selector=read_selector,
                       store_kwargs=dict(**kw))
    return make_kv_service(cfg, sc)


def make_durable_kv(n_keys: int, n_shards: int, directory: str,
                    snapshot_every_rounds: int = 0, n_replicas: int = 1,
                    fsync: str = "batch", mem_frac: float = 0.10,
                    value_width: int = 25, engine: str = "fused",
                    lanes: int = None, dispatch: str = "auto",
                    rc_frac: float = 0.17, index_frac: float = 0.17,
                    **kw):
    """The `make_sharded_kv` / `make_replicated_kv` store recipe wrapped
    in `core.durability.DurableKV`: CPR-style async snapshots into
    `directory` plus a write-ahead slab log.  Same `_shard_cfg` tuning as
    the non-durable bench stores, so durable vs plain comparisons isolate
    the durability tax and nothing else."""
    from repro.core.durability import DurabilityConfig
    cfg = _shard_cfg(n_keys, n_shards, mem_frac, value_width, engine,
                     rc_frac, index_frac, lanes, mode="f2")
    sc = ServiceConfig(n_shards=n_shards, lanes=lanes, dispatch=dispatch,
                       n_replicas=n_replicas,
                       durability=DurabilityConfig(
                           dir=directory,
                           snapshot_every_rounds=snapshot_every_rounds,
                           fsync=fsync),
                       store_kwargs=dict(**kw))
    return make_kv_service(cfg, sc)


def make_session_kv(n_keys: int, n_shards: int, max_sessions: int = 8,
                    session_depth: int = 64, mem_frac: float = 0.10,
                    value_width: int = 25, engine: str = "fused",
                    lanes: int = None, dispatch: str = "auto",
                    rc_frac: float = 0.17, index_frac: float = 0.17,
                    rebalance_cfg: RebalanceConfig = None, **kw):
    """The async serving stack over the `make_sharded_kv` store recipe:
    a `KVSessionService` whose pool packs pending ops from up to
    `max_sessions` concurrent sessions into every routed round.  Same
    `_shard_cfg` tuning as the synchronous bench stores, so session vs
    synchronous comparisons isolate the scheduling change."""
    cfg = _shard_cfg(n_keys, n_shards, mem_frac, value_width, engine,
                     rc_frac, index_frac, lanes, mode="f2")
    sc = ServiceConfig(n_shards=n_shards, lanes=lanes, dispatch=dispatch,
                       rebalance_cfg=rebalance_cfg,
                       max_sessions=max_sessions,
                       session_depth=session_depth,
                       store_kwargs=dict(**kw))
    return make_session_service(cfg, sc)


def load_store(kv: KV, n_keys: int, batch: int = 4096, seed: int = 1):
    rng = np.random.default_rng(seed)
    for start in range(0, n_keys, batch):
        keys = np.arange(start, min(start + batch, n_keys), dtype=np.int32)
        if len(keys) < batch:
            keys = np.pad(keys, (0, batch - len(keys)), mode="edge")
        vals = rng.integers(0, 127, (batch, kv.cfg.value_width)).astype(np.int32)
        kv.upsert(keys, vals)
    return kv


@dataclasses.dataclass
class RunResult:
    ops: int
    wall_s: float
    modeled_s: float
    read_bytes: int
    write_bytes: int
    read_ops: int
    user_bytes: int

    @property
    def modeled_kops(self) -> float:
        return self.ops / self.modeled_s / 1e3 if self.modeled_s else float("inf")

    @property
    def wall_kops(self) -> float:
        return self.ops / self.wall_s / 1e3

    @property
    def read_amp(self) -> float:
        return self.read_bytes / max(self.user_bytes, 1)

    @property
    def write_amp(self) -> float:
        return self.write_bytes / max(self.user_bytes, 1)


def run_workload(kv: KV, workload: str, zipf: Zipf, n_ops: int,
                 batch: int = 4096, seed: int = 2, warmup_ops: int = 0,
                 insert_base: int = 0) -> RunResult:
    rng = np.random.default_rng(seed)
    vw = kv.cfg.value_width
    ins = insert_base
    for _ in range(warmup_ops // batch):
        keys, ops, vals, n_ins = make_ops(rng, workload, zipf, batch, vw, ins)
        ins += n_ins
        kv.apply(keys, ops, vals)
    io0 = kv.io_stats()
    t0 = time.perf_counter()
    done = 0
    for _ in range(max(1, n_ops // batch)):
        keys, ops, vals, n_ins = make_ops(rng, workload, zipf, batch, vw, ins)
        ins += n_ins
        kv.apply(keys, ops, vals)
        done += batch
    import jax
    jax.block_until_ready(kv.state.hot.tail)
    wall = time.perf_counter() - t0
    io1 = kv.io_stats()
    rb = io1["read_bytes"] - io0["read_bytes"]
    wb = io1["write_bytes"] - io0["write_bytes"]
    ro = io1["read_ops"] - io0["read_ops"]
    modeled = max(ro / READ_IOPS, rb / READ_BW) + wb / WRITE_BW
    user = done * (16 + 4 * vw)
    return RunResult(ops=done, wall_s=wall, modeled_s=modeled,
                     read_bytes=rb, write_bytes=wb, read_ops=ro,
                     user_bytes=user)
