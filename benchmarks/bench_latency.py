"""Request-level latency benchmark: per-phase p50/p99 through the full
serving stack, plus the obs-enabled vs. obs-disabled throughput gate.

Drives an identical YCSB-A stream through two identically-built serving
stacks — `KVSessionService` over `DurableKV` over a host-tier
`ShardedKV` — one with `repro.obs` armed, one with the kill-switch off.
The store is first loaded until the live log spills the device cold ring
>= 2x (so promotes and deferral rounds are real, not synthetic), then a
ticketed session lap exercises queue/pack/apply/fsync/e2e and a
full-keyspace wide read exercises deferral/promote.

`--tiny` is the CI gate:

* enabled/disabled throughput ratio >= 0.95,
* the two sides' collected outputs are bit-exact (kill-switch contract),
* all seven `f2_latency_seconds` phases report p99 >= p50 > 0,
* host-tier spill factor >= 2,
* the demo threshold rule provably fires (journaled `alert.fired`).

    PYTHONPATH=src python benchmarks/bench_latency.py [--tiny] \
        [--out BENCH_latency.json] [--alerts-out alerts.json]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

import numpy as np

sys.path.insert(0, "src")

import jax

from repro import obs
from repro.core import F2Config
from repro.core.durability import DurabilityConfig
from repro.core.types import OP_DELETE, OP_READ, OP_RMW, OP_UPSERT
from repro.obs import export, latency, rules
from repro.serve.serve_step import ServiceConfig, make_session_service

try:                                    # python benchmarks/bench_latency.py
    from bench_mixed import MIXES, mixed_batches
except ImportError:                     # python -m benchmarks.bench_latency
    from benchmarks.bench_mixed import MIXES, mixed_batches

PHASES = ("queue", "pack", "apply", "deferral", "promote", "fsync", "e2e")
GATE_RATIO = 0.95          # enabled must keep >= 95% of disabled throughput
SPILL_FLOOR = 2.0          # live log must span >= 2 device cold rings


def _cfg(tiny: bool) -> F2Config:
    """Host-tier store geometry: a cold ring the live log outgrows, so
    reads genuinely promote from host memory (the spilled-test regime)."""
    if tiny:
        return F2Config(hot_index_size=1 << 10, hot_capacity=1 << 12,
                        hot_mem=1 << 9, cold_capacity=1 << 9,
                        cold_mem=1 << 7, n_chunks=1 << 8, chunk_slots=16,
                        chunklog_capacity=1 << 12, chunklog_mem=1 << 8,
                        rc_capacity=1 << 8, host_tier=True,
                        host_chunk_records=16, host_cache_chunks=48,
                        host_resident_frac=0.5, host_prefetch=1,
                        value_width=2, chain_max=24, engine="jnp")
    return F2Config(hot_index_size=1 << 12, hot_capacity=1 << 14,
                    hot_mem=1 << 11, cold_capacity=1 << 11,
                    cold_mem=1 << 9, n_chunks=1 << 9, chunk_slots=16,
                    chunklog_capacity=1 << 14, chunklog_mem=1 << 10,
                    rc_capacity=1 << 10, host_tier=True,
                    host_chunk_records=16, host_cache_chunks=96,
                    host_resident_frac=0.5, host_prefetch=1,
                    value_width=2, chain_max=24, engine="jnp")


def _wal_dir() -> str:
    base = "/dev/shm" if os.path.isdir("/dev/shm") else None
    return tempfile.mkdtemp(prefix="bench_latency_wal_", dir=base)


def _spill_factor(store) -> float:
    c = jax.device_get(store.state.cold)
    return float(np.max(np.asarray(c.tail) - np.asarray(c.begin))
                 / store.cfg.cold_capacity)


def _load(store, n_keys: int, n_steps: int, B: int) -> None:
    """Uniform mixed-op drive until the log spills the cold ring (the
    write-heavy-but-not-pure mix keeps each batch's chain pins inside
    the chunk cache); every batch fsyncs, feeding the fsync phase."""
    rng = np.random.default_rng(7)
    for step in range(n_steps):
        keys = rng.integers(1, n_keys + 1, size=B).astype(np.int32)
        ops = rng.choice([OP_READ, OP_UPSERT, OP_RMW, OP_DELETE], size=B,
                         p=[.5, .3, .15, .05]).astype(np.int32)
        vals = np.stack([keys * 3 + step, keys * 5 + 1],
                        axis=1).astype(np.int32)
        store.apply(keys, ops, vals)


LAPS_PER_WINDOW = 3         # a single ~ms lap is too noisy a timing unit


def _build_side(enabled: bool, tiny: bool, n_keys: int, B: int,
                load_steps: int, batches) -> dict:
    """Build, load and warm one serving stack under the given obs mode.
    The obs kill-switch is process-global, so the caller flips it per
    timing window afterwards; each side keeps its own store + WAL dir."""
    obs.configure(enabled=enabled)
    svc = make_session_service(
        _cfg(tiny),
        ServiceConfig(n_shards=1, pack_lanes=32, max_sessions=4,
                      session_depth=128,
                      durability=DurabilityConfig(dir=_wal_dir()),
                      store_kwargs=dict(compact_batch=128, donate=False)))
    store = svc.kv                          # DurableKV over ShardedKV
    _load(store, n_keys, load_steps, B)
    spill = _spill_factor(store)

    keys, ops, vals = batches
    sessions = [svc.open_session() for _ in range(2)]
    # untimed warmup lap: compiles the pack/commit/ticket-gather kernels
    # (and creates the metric families) so the timed laps are steady-state
    for b in range(keys.shape[0]):
        sessions[b % len(sessions)].enqueue(keys[b], ops[b], vals[b])
        svc.step()
    svc.run_until_idle()
    for s in sessions:
        s.drain()
    return dict(enabled=enabled, svc=svc, store=store, spill=spill,
                sessions=sessions, outputs=[], best=float("inf"))


def _lap(side: dict, batches) -> None:
    """One full session lap on `side`, appending drained outputs."""
    keys, ops, vals = batches
    svc, sessions = side["svc"], side["sessions"]
    for b in range(keys.shape[0]):
        s = sessions[b % len(sessions)]
        s.enqueue(keys[b], ops[b], vals[b])
        svc.step()
    svc.run_until_idle()
    for s in sessions:
        _tk, st, v = s.drain()
        side["outputs"].append((np.asarray(st).tolist(),
                                np.asarray(v).tolist()))


def run_ab(tiny: bool, n_keys: int, B: int, load_steps: int, batches,
           repeats: int) -> tuple[dict, dict]:
    """Build both stacks, then alternate timed windows between them,
    flipping only the obs kill-switch per window.  Interleaving makes
    the two sides sample the same machine conditions — sequential sides
    minutes apart measure load drift, not instrumentation overhead."""
    obs.configure(enabled=True, reset=True)
    # demo rules: the first provably fires once tickets complete, the
    # second stays quiet (sanity that firing is not vacuous)
    rules.add_rule("e2e-traffic",
                   "count(f2_latency_seconds{phase=e2e}) >= 1")
    rules.add_rule("e2e-slow",
                   "p99(f2_latency_seconds{phase=e2e}) > 10.0")
    on = _build_side(True, tiny, n_keys, B, load_steps, batches)
    # no reset: the enabled side's registry/clock state must survive
    off = _build_side(False, tiny, n_keys, B, load_steps, batches)

    for _ in range(repeats):
        for side in (off, on):          # both sides sampled every round
            obs.configure(enabled=side["enabled"])
            t0 = time.perf_counter()
            for _ in range(LAPS_PER_WINDOW):
                _lap(side, batches)
            side["best"] = min(side["best"], time.perf_counter() - t0)

    # the wide read: every key in one batch — below-floor lanes defer and
    # promote through the host tier (splitting the batch if the walk
    # paths outgrow the chunk cache)
    all_keys = np.arange(1, n_keys + 1, dtype=np.int32)
    for side in (off, on):
        obs.configure(enabled=side["enabled"])
        st, v = side["store"].read(all_keys)
        side["outputs"].append((np.asarray(st).tolist(),
                                np.asarray(v).tolist()))

    obs.configure(enabled=True)
    on["stats"] = on["svc"].stats()         # fold point: drains obs queues
    rules.evaluate()                        # final explicit alert pass
    n_ops = batches[0].shape[0] * B * LAPS_PER_WINDOW
    for side in (off, on):
        side["n_ops"] = n_ops
        side["ops_per_s"] = n_ops / side["best"]
    on["phases"] = latency.summary()
    on["alerts"] = rules.ENGINE.snapshot()
    on["alert_events"] = obs.journal.events("alert.")
    return off, on


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI gate mode: minimal sizes, asserts the "
                         f"{GATE_RATIO:.0%} throughput floor, bit-exact "
                         "outputs, all-phase coverage, spill >= "
                         f"{SPILL_FLOOR:g}x and a firing alert")
    ap.add_argument("--out", default=None, help="write BENCH JSON here")
    ap.add_argument("--alerts-out", default=None,
                    help="write the alert engine snapshot + journaled "
                         "alert events here")
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)

    if args.tiny:
        n_keys, B, n_batches, load_steps, repeats = 4096, 64, 6, 320, 4
    else:
        n_keys, B, n_batches, load_steps, repeats = 1 << 14, 128, 16, 640, 3
    if args.repeats:
        repeats = args.repeats

    rng = np.random.default_rng(23)
    batches = mixed_batches(rng, MIXES["A"], n_keys, 0.99, B, n_batches,
                            _cfg(args.tiny).value_width)

    off, on = run_ab(args.tiny, n_keys, B, load_steps, batches, repeats)
    ratio = on["ops_per_s"] / off["ops_per_s"]
    outputs_match = on["outputs"] == off["outputs"]
    fired = [r["name"] for r in on["alerts"]["rules"] if r["fired_total"]]

    print(f"disabled: {off['ops_per_s'] / 1e3:9.2f} kops/s  "
          f"(spill {off['spill']:.2f}x)")
    print(f"enabled:  {on['ops_per_s'] / 1e3:9.2f} kops/s  "
          f"(spill {on['spill']:.2f}x)")
    print(f"enabled/disabled throughput ratio: {ratio:.3f}")
    print(f"outputs bit-exact across sides: {outputs_match}")
    print(f"alerts fired: {fired}  "
          f"(journaled: {len(on['alert_events'])} events)")
    print(f"{'phase':>9}  {'count':>7}  {'mean':>10}  {'p50':>10}  "
          f"{'p99':>10}")
    for ph in PHASES:
        s = on["phases"].get(ph)
        if s:
            print(f"{ph:>9}  {s['count']:>7}  {s['mean']:>10.3e}  "
                  f"{s['p50']:>10.3e}  {s['p99']:>10.3e}")
        else:
            print(f"{ph:>9}  {'-':>7}")

    results = dict(
        backend=jax.default_backend(), n_keys=n_keys, batch=B,
        n_batches=n_batches, tiny=bool(args.tiny),
        disabled=off["ops_per_s"], enabled=on["ops_per_s"], ratio=ratio,
        spill=on["spill"], outputs_match=outputs_match,
        alerts_fired=fired, phases=on["phases"])
    if args.out:
        # written while the enabled side's registry is still live, so the
        # envelope's metrics_snapshot carries the full metric catalog
        export.write_bench_json(args.out, bench="latency",
                                config=vars(args), results=results)
        print(f"wrote {args.out}")
    if args.alerts_out:
        with open(args.alerts_out, "w") as f:
            json.dump({"engine": on["alerts"],
                       "journal": on["alert_events"]}, f, indent=2,
                      default=str)
        print(f"wrote {args.alerts_out}")
    obs.configure(enabled=False)

    assert outputs_match, \
        "collected outputs differ between obs enabled and disabled"
    if args.tiny:
        assert on["spill"] >= SPILL_FLOOR, \
            f"host tier not spilled: {on['spill']:.2f}x < {SPILL_FLOOR}x"
        for ph in PHASES:
            s = on["phases"].get(ph)
            assert s and s["count"] > 0, f"phase {ph!r} recorded no samples"
            assert s["p99"] >= s["p50"] > 0, (ph, s)
        assert "e2e-traffic" in fired and on["alert_events"], \
            "threshold alert did not fire through the fold points"
        assert ratio >= GATE_RATIO, (
            f"latency-instrumentation overhead gate failed: "
            f"enabled/disabled = {ratio:.3f} < {GATE_RATIO}")
    return results


if __name__ == "__main__":
    main()
