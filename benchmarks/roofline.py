"""Roofline report from the dry-run artifacts (EXPERIMENTS.md SRoofline).

    PYTHONPATH=src python -m benchmarks.roofline dryrun_results.json

Per (arch x shape x mesh): the three terms (compute / memory / collective,
in seconds per step per device), the dominant bottleneck, MODEL_FLOPS /
HLO_FLOPs usefulness ratio, and a one-line 'what would move the dominant
term' note.
"""
from __future__ import annotations

import json
import sys
from typing import Dict, List

NOTES = {
    ("collective", "train"): "cut ZeRO-3 regather: TP for attn/MLP weights "
                             "or overlap AG with layer compute",
    ("collective", "prefill"): "shard KV heads instead of gathering; fuse "
                               "qkv collectives",
    ("collective", "decode"): "batch more sequences per chip; widen "
                              "flash-decode combine groups",
    ("compute", "train"): "already MXU-bound: raise per-chip batch or "
                          "accept (good place to be)",
    ("compute", "prefill"): "MXU-bound: quantize KV / widen blocks",
    ("compute", "decode"): "decode rarely compute-bound; check batching",
    ("memory", "train"): "recompute less (selective remat) or fuse "
                         "elementwise chains",
    ("memory", "prefill"): "KV cache layout: pack head_dim for fewer "
                           "HBM transactions",
    ("memory", "decode"): "decode is HBM-bound by weights+KV streaming: "
                          "quantize weights/KV to 8-bit",
}


def kind_of(shape: str) -> str:
    return {"train_4k": "train", "prefill_32k": "prefill"}.get(shape, "decode")


def fmt_row(r: Dict) -> str:
    roof = r["roofline"]
    t = max(roof["compute_s"], roof["memory_s"], roof["collective_s"])
    mf = r.get("model_flops", 0.0) / r["n_devices"]
    useful = mf / max(r["cost"]["flops_per_device"], 1.0)
    mfu_bound = mf / 197e12 / t if t else 0.0
    return (f"| {r['arch']} | {r['shape']} | {r['n_devices']} "
            f"| {roof['compute_s']*1e3:9.3f} | {roof['memory_s']*1e3:9.3f} "
            f"| {roof['collective_s']*1e3:9.3f} | {roof['dominant']:10s} "
            f"| {useful:5.2f} | {mfu_bound*100:5.1f}% |")


def main(path: str = "dryrun_results.json") -> None:
    recs = json.load(open(path))
    ok = [r for r in recs if r["status"] == "ok"]
    print("| arch | shape | devs | compute ms | memory ms | collective ms "
          "| dominant | useful | roofline-frac |")
    print("|---|---|---|---|---|---|---|---|---|")
    for r in sorted(ok, key=lambda r: (r["n_devices"], r["arch"], r["shape"])):
        print(fmt_row(r))
    print()
    # bottleneck census + hillclimb candidates
    by_dom: Dict[str, int] = {}
    worst: List = []
    for r in ok:
        d = r["roofline"]["dominant"]
        by_dom[d] = by_dom.get(d, 0) + 1
        t = max(r["roofline"].values(), key=lambda v: v if isinstance(v, float) else 0)
        mf = r.get("model_flops", 0.0) / r["n_devices"]
        tt = max(r["roofline"]["compute_s"], r["roofline"]["memory_s"],
                 r["roofline"]["collective_s"])
        frac = mf / 197e12 / tt if tt else 0.0
        worst.append((frac, r["arch"], r["shape"], r["n_devices"], d))
    print("dominant-term census:", by_dom)
    print("\nlowest roofline fraction (hillclimb candidates):")
    for frac, a, s, n, d in sorted(worst)[:6]:
        k = kind_of(s)
        print(f"  {a} x {s} x {n}d: {frac*100:.1f}% ({d}) -> "
              f"{NOTES.get((d, k), '')}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "dryrun_results.json")
