"""Live-rebalancing benchmark: skewed YCSB with a mid-run hot-key shift.

Hash partitioning spreads *keys* uniformly, so the adversarial case for a
sharded store is a hot set that clusters in hash space: here a Zipf-drawn
hot set confined to the buckets of ONE shard serves `hot_frac` of all
traffic (the rest is uniform).  Mid-run the hot set *shifts* to a
different shard's buckets — the moment a static hash partition leaves one
shard saturated while the others idle (paper S1/S3: skew concentrates
load; FOCUS/"Learning KV Store Design": placement must follow the
workload).

Two variants run the identical op stream:

    baseline    — ShardedKV with the rebalancer disarmed (static map)
    rebalance   — ShardedKV with the occupancy-driven rebalancer armed

and each post-shift window reports wall-clock kops, routed rounds/batch
(deferral pressure on the hot shard: lanes < B makes overload cost real
rounds), and the measured per-shard traffic imbalance (max/mean of routed
lanes, from `kv.shard_stats()` — the same struct the rebalancer itself
consumes; `bench_shards.py` reports from it too).

    PYTHONPATH=src python benchmarks/bench_rebalance.py [--tiny] [--out f.json]

`--tiny` is the CI smoke mode (`BENCH_rebalance.json` artifact): minimal
sizes plus the gate — the rebalanced variant must (a) actually migrate,
and (b) end the post-shift phase with strictly lower measured imbalance
than the no-rebalance baseline.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

sys.path.insert(0, "src")
sys.path.insert(0, ".")

import jax
import jax.numpy as jnp

from benchmarks.bench_mixed import zipf_keys
from benchmarks.bench_shards import build_sharded
from repro.core import OP_READ, OP_UPSERT, shard_router
from repro.core.rebalance import RebalanceConfig, imbalance_of
from repro.core.sharded import ShardedKV
from repro.obs import export


def shard_keyset(n_keys: int, shard: int, n_shards: int) -> np.ndarray:
    """Keys whose default-map route is `shard` (hot set clustered in hash
    space — the case static hash partitioning cannot spread)."""
    keys = np.arange(n_keys, dtype=np.int32)
    sid = np.asarray(shard_router.shard_of(jnp.asarray(keys), n_shards))
    return keys[sid == shard]


def skewed_batches(rng, n_keys: int, hot_keys: np.ndarray, hot_frac: float,
                   theta: float, B: int, n_batches: int, vw: int,
                   read_frac: float = 0.95):
    """YCSB-B-style batches: `hot_frac` of lanes Zipf-drawn from the hot
    set, the rest uniform over the whole key space."""
    n_hot = int(B * hot_frac)
    hot_draw = hot_keys[zipf_keys(rng, len(hot_keys), theta,
                                  (n_batches, n_hot))]
    uni_draw = rng.integers(0, n_keys, (n_batches, B - n_hot))
    keys = np.concatenate([hot_draw, uni_draw], axis=1).astype(np.int32)
    # interleave so deferral pressure is not front-loaded in the slab
    perm = rng.permutation(B)
    keys = keys[:, perm]
    ops = np.where(rng.random((n_batches, B)) < read_frac,
                   OP_READ, OP_UPSERT).astype(np.int32)
    vals = rng.integers(0, 100, (n_batches, B, vw)).astype(np.int32)
    return keys, ops, vals


def build(n_keys: int, S: int, W: int, vw: int, engine: str,
          rebalance_on: bool) -> ShardedKV:
    """The bench_shards store recipe (same tuning, same preload) with the
    rebalancer armed or disarmed on top."""
    rb = RebalanceConfig(enabled=rebalance_on, buckets_per_shard=8,
                         threshold=1.25, check_every=4, decay=0.8,
                         min_traffic=2.0 * W, migrate_batch=min(W, 512))
    return build_sharded(n_keys, S, W, vw, engine, rebalance_cfg=rb)


def run_window(kv: ShardedKV, batches) -> dict:
    keys, ops, vals = batches
    n_batches, B = keys.shape
    rounds0, lanes0 = kv.rounds, kv.routed_lanes.copy()
    mig0 = kv.migrations
    t0 = time.perf_counter()
    for j in range(n_batches):
        kv.apply(keys[j], ops[j], vals[j])
    jax.block_until_ready(kv.state.hot.tail)
    wall = time.perf_counter() - t0
    stats = kv.shard_stats()
    return dict(
        ops_per_s=n_batches * B / wall,
        seconds=wall,
        rounds_per_batch=(kv.rounds - rounds0) / n_batches,
        imbalance_max_over_mean=imbalance_of(stats.routed_lanes - lanes0),
        migrations=kv.migrations - mig0,
        stats=kv.stats(),       # the unified nested KVProtocol shape
    )


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: minimal sizes + imbalance gate")
    ap.add_argument("--out", default=None, help="write results JSON here")
    ap.add_argument("--engine", default="fused",
                    choices=("jnp", "fused", "fused_ref", "fused_pallas"))
    args = ap.parse_args(argv)

    S = 4
    if args.tiny:
        n_keys, W, vw = 4096, 256, 2
        pre_batches, win_batches, n_windows = 6, 4, 3
        theta, hot_frac = 0.99, 0.75
    else:
        n_keys, W, vw = 1 << 15, 1024, 8
        pre_batches, win_batches, n_windows = 12, 8, 4
        theta, hot_frac = 0.99, 0.75
    B = S * W // 2

    results = dict(backend=jax.default_backend(), n_keys=n_keys, lanes=W,
                   batch=B, tiny=bool(args.tiny), engine=args.engine,
                   hot_frac=hot_frac, theta=theta, variants={})
    for name, rebalance_on in (("baseline", False), ("rebalance", True)):
        kv = build(n_keys, S, W, vw, args.engine, rebalance_on)
        rng = np.random.default_rng(23)
        # phase 1: hot set clustered on shard 0's buckets
        hot_a = shard_keyset(n_keys, 0, S)
        pre = run_window(kv, skewed_batches(
            rng, n_keys, hot_a, hot_frac, theta, B, pre_batches, vw))
        # mid-run hot-key shift: the hot set jumps to shard 1's buckets
        hot_b = shard_keyset(n_keys, 1, S)
        windows = [run_window(kv, skewed_batches(
            rng, n_keys, hot_b, hot_frac, theta, B, win_batches, vw))
            for _ in range(n_windows)]
        kv.check_invariants()
        row = dict(pre_shift=pre, post_shift=windows,
                   migrations_total=kv.migrations,
                   migrated_records=kv.migrated_records,
                   migrated_buckets=kv.migrated_buckets,
                   final_imbalance=windows[-1]["imbalance_max_over_mean"],
                   recovery_kops=(windows[-1]["ops_per_s"]
                                  / max(windows[0]["ops_per_s"], 1e-9)))
        results["variants"][name] = row
        print(f"{name:>9}: pre imb={pre['imbalance_max_over_mean']:.2f} "
              f"post imb=" + "->".join(
                  f"{w['imbalance_max_over_mean']:.2f}" for w in windows)
              + f" rounds/batch={windows[-1]['rounds_per_batch']:.2f}"
              f" kops={windows[-1]['ops_per_s'] / 1e3:.1f}"
              f" migrations={kv.migrations}"
              f" moved={kv.migrated_records}")

    base = results["variants"]["baseline"]
    reb = results["variants"]["rebalance"]
    results["imbalance_reduction"] = (base["final_imbalance"]
                                      - reb["final_imbalance"])
    if args.tiny:
        # the smoke gate: the rebalancer must fire on the shifted hot set
        # and end with strictly lower measured imbalance than the static
        # map (throughput recovery is reported, not gated: CPU wall clock
        # is too noisy at tiny scale)
        assert reb["migrations_total"] >= 1, "rebalancer never migrated"
        assert base["migrations_total"] == 0
        assert reb["final_imbalance"] < base["final_imbalance"], (
            f"rebalancing did not reduce post-shift imbalance: "
            f"{reb['final_imbalance']:.3f} vs {base['final_imbalance']:.3f}")

    if args.out:
        export.write_bench_json(args.out, bench="rebalance",
                                config=vars(args),
                                results=results)
        print(f"wrote {args.out}")
    return results


if __name__ == "__main__":
    main()
