"""Fig 2: FASTER's single-log 'death spiral' under a larger-than-memory
RMW workload vs F2's tiered logs (hot tail undisturbed by compaction)."""
from __future__ import annotations

from repro.core import KV

from .harness import Zipf, load_store, make_f2_config, make_faster_kv, run_workload


def run(n_keys: int = 1 << 16, windows: int = 14, win_ops: int = 1 << 14,
        batch: int = 4096, engine: str = "fused", seed: int = 2):
    zipf = Zipf(n_keys, 0.99)
    out = {}
    for system in ("FASTER", "F2"):
        if system == "F2":
            kv = KV(make_f2_config(n_keys, 0.10, engine=engine), mode="f2",
                    compact_batch=batch, trigger=0.8, compact_frac=0.15)
        else:
            kv = make_faster_kv(n_keys, 0.10, batch=batch, engine=engine)
        load_store(kv, n_keys, batch)
        series = []
        for w in range(windows):
            # per-seed window ranges are disjoint (so seed sweeps are
            # actually decorrelated); the default (seed=2) reproduces the
            # original 100+w series exactly
            r = run_workload(kv, "F", zipf, win_ops, batch,
                             seed=(seed - 2) * 1000 + 100 + w)
            series.append(r.modeled_kops)
        kv.check_invariants()
        out[system] = dict(kops_per_window=series,
                           compactions=kv.compactions)
    return out


def report(res) -> str:
    lines = ["fig2: modeled kops per window (RMW-heavy, tight budget)"]
    for system, d in res.items():
        ser = " ".join(f"{x:8.1f}" for x in d["kops_per_window"])
        lines.append(f"  {system:7s} [{d['compactions']:3d} compactions]: {ser}")
    f = res["FASTER"]["kops_per_window"]
    f2 = res["F2"]["kops_per_window"]
    # post-collapse regime = second half of the horizon (FASTER hits its
    # budget mid-run, then oscillates: stall, recover, re-stall — Fig 2)
    h = len(f) // 2
    mean = lambda xs: sum(xs) / len(xs)
    lines.append(
        f"  post-budget mean F2/FASTER: {mean(f2[h:]) / max(mean(f[h:]), 1e-9):.2f}x"
        f" | stall depth (min window) FASTER {min(f[h:]):.0f} vs F2 {min(f2[h:]):.0f} kops"
        f" ({min(f2[h:]) / max(min(f[h:]), 1e-9):.1f}x)")
    return "\n".join(lines)
