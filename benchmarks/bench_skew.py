"""Fig 12: throughput vs Zipfian skew (alpha in [3,1000] -> theta) for
YCSB-A and YCSB-B."""
from __future__ import annotations

from repro.core import KV

from .harness import Zipf, load_store, make_f2_config, make_faster_kv, run_workload
from .ycsb import ALPHA_TO_THETA


def run(n_keys: int = 1 << 16, n_ops: int = 1 << 15, batch: int = 4096,
        alphas=(3, 10, 100, 1000), engine: str = "fused", seed: int = 2):
    out = {}
    for system in ("F2", "FASTER"):
        out[system] = {}
        for wl in ("A", "B"):
            row = {}
            for a in alphas:
                zipf = Zipf(n_keys, ALPHA_TO_THETA[a])
                if system == "F2":
                    kv = KV(make_f2_config(n_keys, 0.10, engine=engine),
                            mode="f2", compact_batch=batch)
                else:
                    kv = make_faster_kv(n_keys, 0.10, batch=batch,
                                        engine=engine)
                load_store(kv, n_keys, batch)
                r = run_workload(kv, wl, zipf, n_ops, batch, seed=seed,
                                 warmup_ops=n_keys)
                kv.check_invariants()
                row[a] = r.modeled_kops
            out[system][wl] = row
    return out


def report(res) -> str:
    lines = ["fig12: modeled kops vs skew alpha"]
    for system, per_wl in res.items():
        for wl, row in per_wl.items():
            s = " ".join(f"a={a}:{v:9.1f}" for a, v in row.items())
            lines.append(f"  {system:7s} YCSB-{wl}: {s}")
    return "\n".join(lines)
