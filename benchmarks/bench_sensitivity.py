"""Fig 14: (left) cold-index hash-chunk size sweep — throughput + write
amplification; (right) read-cache size sweep for read-heavy workloads."""
from __future__ import annotations

from repro.core import KV

from .harness import Zipf, load_store, make_f2_config, run_workload


def run_chunks(n_keys: int = 1 << 16, n_ops: int = 1 << 15,
               batch: int = 4096, chunk_slots=(8, 16, 32, 128, 512),
               engine: str = "fused", seed: int = 2):
    """chunk_slots * 8B = chunk bytes: 64B .. 4KiB (paper's x-axis)."""
    zipf = Zipf(n_keys, 0.99)
    out = {}
    for wl in ("A", "B"):
        row = {}
        for cs in chunk_slots:
            kv = KV(make_f2_config(n_keys, 0.10, chunk_slots=cs,
                                   engine=engine),
                    mode="f2", compact_batch=batch)
            load_store(kv, n_keys, batch)
            r = run_workload(kv, wl, zipf, n_ops, batch, seed=seed,
                             warmup_ops=n_keys)
            kv.check_invariants()
            row[cs * 8] = (r.modeled_kops, r.write_amp)
        out[wl] = row
    return out


def run_rc(n_keys: int = 1 << 16, n_ops: int = 1 << 15, batch: int = 4096,
           rc_fracs=(0.0, 0.08, 0.17, 0.34), engine: str = "fused",
           seed: int = 2):
    zipf = Zipf(n_keys, 0.99)
    out = {}
    for wl in ("B", "C"):
        row = {}
        for f in rc_fracs:
            kv = KV(make_f2_config(n_keys, 0.10, rc_frac=max(f, 0.01),
                                   rc_enabled=(f > 0), engine=engine),
                    mode="f2", compact_batch=batch)
            load_store(kv, n_keys, batch)
            r = run_workload(kv, wl, zipf, n_ops, batch, seed=seed,
                             warmup_ops=n_keys)
            kv.check_invariants()
            row[f] = r.modeled_kops
        out[wl] = row
    return out


def report(chunks, rc) -> str:
    lines = ["fig14-left: chunk-size -> (modeled kops, write-amp)"]
    for wl, row in chunks.items():
        s = " ".join(f"{b}B:({v[0]:8.1f},{v[1]:4.2f})" for b, v in row.items())
        lines.append(f"  YCSB-{wl}: {s}")
    lines.append("fig14-right: read-cache budget fraction -> modeled kops")
    for wl, row in rc.items():
        s = " ".join(f"{f*100:4.1f}%:{v:9.1f}" for f, v in row.items())
        lines.append(f"  YCSB-{wl}: {s}")
    return "\n".join(lines)
