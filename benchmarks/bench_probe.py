"""Microbenchmark: fused vs. unfused probe engine on the read hot path.

Times `store.read_batch` under each probe backend across skew levels
(zipfian thetas), on a store preloaded so reads hit every tier: hot
in-memory records, stable-tier records, cold records, and RC replicas.
Reports wall-clock batch reads/s per (skew, engine) as JSON.

    PYTHONPATH=src python benchmarks/bench_probe.py [--tiny] [--out f.json]

`--tiny` is the CI smoke mode: a minimal store, one skew level, few
iterations, plus a `fused_pallas` interpret-mode sanity lap — it proves the
kernel path end-to-end on any backend and seeds the perf-trajectory
artifact that later PRs extend.
"""
from __future__ import annotations

import argparse
import dataclasses
import functools
import sys
import time

import numpy as np

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import KV, F2Config, store
from repro.obs import export


def build_store(n_keys: int, cfg: F2Config) -> KV:
    kv = KV(cfg, mode="f2", trigger=2.0, donate=False)
    keys = np.arange(n_keys, dtype=np.int32)
    vals = np.stack([keys] * cfg.value_width, 1).astype(np.int32)
    B = 1024
    for off in range(0, n_keys, B):
        kv.upsert(keys[off:off + B], vals[off:off + B])
    kv.compact_hot_cold(int(kv.state.hot.tail) // 2)   # half the keys go cold
    kv.read(keys[:: max(1, n_keys // 512)])            # seed the read cache
    return kv


def zipf_batches(n_keys: int, theta: float, B: int, n_batches: int,
                 seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if theta <= 0.01:
        draws = rng.integers(0, n_keys, (n_batches, B))
    else:
        ranks = np.arange(1, n_keys + 1, dtype=np.float64)
        p = ranks ** -theta
        p /= p.sum()
        draws = rng.choice(n_keys, (n_batches, B), p=p)
    # scramble rank->key so hot keys spread over the hash space (YCSB)
    perm = rng.permutation(n_keys)
    return perm[draws].astype(np.int32)


def time_engine(kv: KV, cfg: F2Config, engine: str, batches: np.ndarray,
                repeats: int) -> dict:
    ecfg = dataclasses.replace(cfg, engine=engine)
    read = jax.jit(functools.partial(store.read_batch, ecfg, admit_rc=False))
    state = kv.state
    act = jnp.ones((batches.shape[1],), bool)
    dev = [jnp.asarray(b) for b in batches]
    _, status, vals = read(state, dev[0], act)          # compile
    jax.block_until_ready((status, vals))
    n_found = int(jnp.sum(status == 1))
    t0 = time.perf_counter()
    for _ in range(repeats):
        for kb in dev:
            _, status, vals = read(state, kb, act)
    jax.block_until_ready((status, vals))
    dt = time.perf_counter() - t0
    n_ops = repeats * batches.shape[0] * batches.shape[1]
    return dict(engine=engine, ops_per_s=n_ops / dt, seconds=dt,
                n_ops=n_ops, found_first_batch=n_found)


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--tiny", action="store_true",
                    help="CI smoke mode: minimal sizes + interpret kernel lap")
    ap.add_argument("--out", default=None, help="write results JSON here")
    ap.add_argument("--batch", type=int, default=None)
    ap.add_argument("--repeats", type=int, default=None)
    args = ap.parse_args(argv)

    if args.tiny:
        n_keys, B, n_batches, repeats = 512, 128, 2, 1
        thetas = [0.99]
        cfg = F2Config(hot_index_size=1 << 9, hot_capacity=1 << 11,
                       hot_mem=1 << 8, cold_capacity=1 << 13, cold_mem=1 << 7,
                       n_chunks=1 << 7, chunklog_capacity=1 << 11,
                       chunklog_mem=1 << 6, rc_capacity=1 << 7,
                       value_width=2, chain_max=48)
        engines = ["jnp", "fused_ref", "fused_pallas"]
    else:
        n_keys, B, n_batches, repeats = 1 << 15, 4096, 8, 4
        thetas = [0.0, 0.55, 0.75, 0.99, 1.20]
        cfg = F2Config(hot_index_size=1 << 14, hot_capacity=1 << 17,
                       hot_mem=1 << 14, cold_capacity=1 << 18,
                       cold_mem=1 << 10, n_chunks=1 << 10,
                       chunklog_capacity=1 << 13, chunklog_mem=1 << 8,
                       rc_capacity=1 << 12, value_width=2, chain_max=48)
        engines = ["jnp", "fused"]
    if args.batch:
        B = args.batch
    if args.repeats:
        repeats = args.repeats

    kv = build_store(n_keys, cfg)
    results = dict(backend=jax.default_backend(), n_keys=n_keys, batch=B,
                   tiny=bool(args.tiny), skews=[])
    for theta in thetas:
        batches = zipf_batches(n_keys, theta, B, n_batches)
        row = dict(theta=theta, engines=[])
        for eng in engines:
            r = time_engine(kv, cfg, eng, batches, repeats)
            row["engines"].append(r)
            print(f"theta={theta:<5} engine={eng:<13} "
                  f"{r['ops_per_s'] / 1e3:9.1f} kops/s "
                  f"(found {r['found_first_batch']}/{B} first batch)")
        results["skews"].append(row)

    # smoke-mode sanity: every engine must agree on first-batch hit counts
    for row in results["skews"]:
        counts = {e["found_first_batch"] for e in row["engines"]}
        assert len(counts) == 1, f"engines disagree at theta={row['theta']}: {counts}"

    if args.out:
        export.write_bench_json(args.out, bench="probe",
                                config=vars(args),
                                results=results)
        print(f"wrote {args.out}")
    return results


if __name__ == "__main__":
    main()
