"""Fig 10: F2 vs FASTER throughput on Zipfian YCSB A/B/C/F (modeled NVMe).
Also supplies Table 2 (I/O amplification) numbers for A and B."""
from __future__ import annotations

from typing import Dict

from repro.core import KV

from .harness import (RunResult, Zipf, load_store, make_f2_config,
                      make_faster_kv, run_workload)


def run(n_keys: int = 1 << 16, n_ops: int = 1 << 16, mem_frac: float = 0.10,
        theta: float = None, batch: int = 4096, engine: str = "fused",
        seed: int = 2) -> Dict[str, Dict[str, RunResult]]:
    zipf = Zipf(n_keys, theta or 0.99)
    out: Dict[str, Dict[str, RunResult]] = {}
    for system in ("F2", "FASTER"):
        out[system] = {}
        for wl in ("A", "B", "C", "F"):
            if system == "F2":
                kv = KV(make_f2_config(n_keys, mem_frac, engine=engine),
                        mode="f2", compact_batch=batch)
            else:
                kv = make_faster_kv(n_keys, mem_frac, batch=batch,
                                    engine=engine)
            load_store(kv, n_keys, batch)
            # steady state first: a full dataset pass of warmup so both
            # systems hit their disk budgets (the paper warms with 25M ops
            # then measures 300M — compaction churn included)
            res = run_workload(kv, wl, zipf, n_ops, batch, seed=seed,
                               warmup_ops=n_keys)
            kv.check_invariants()
            out[system][wl] = res
    return out


def report(results) -> str:
    lines = ["fig10: modeled kops (wall kops) | read-amp / write-amp"]
    for system, per_wl in results.items():
        for wl, r in per_wl.items():
            lines.append(
                f"  {system:7s} YCSB-{wl}: {r.modeled_kops:9.1f} kops"
                f" ({r.wall_kops:6.1f} wall) | RA {r.read_amp:6.2f}"
                f" WA {r.write_amp:5.2f}")
    a, b = results["F2"]["A"], results["FASTER"]["A"]
    lines.append(f"  F2/FASTER speedup YCSB-A: {a.modeled_kops/b.modeled_kops:.2f}x")
    return "\n".join(lines)
